"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU), plus hypothesis
property tests on the quantizer kernel's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantizers import QuantSpec, pack_int4
from repro.kernels import ops, ref
from repro.kernels.actquant import act_quant_kernel
from repro.kernels.hadamard import fwht_kernel
from repro.kernels.w4a4 import w4a4_lowrank_matmul_kernel


# ---------------------------------------------------------------------------
# w4a4 fused matmul
# ---------------------------------------------------------------------------


def _make_w4a4_problem(rng, m, k, n, r, dtype):
    xq = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
    sx = jnp.asarray(rng.uniform(0.01, 0.2, (m, 1)), jnp.float32)
    q = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)  # (d_out, d_in)
    wpacked = pack_int4(q).T  # (k//2, n)
    sw = jnp.asarray(rng.uniform(0.01, 0.2, (1, n)), jnp.float32)
    xv = u = None
    if r:
        xv = jnp.asarray(rng.standard_normal((m, r)), jnp.float32)
        u = jnp.asarray(rng.standard_normal((n, r)), dtype)
    return xq, sx, wpacked, sw, xv, u


@pytest.mark.parametrize("m,k,n,r", [
    (16, 64, 32, 0),
    (16, 64, 32, 8),
    (32, 128, 64, 16),
    (8, 32, 128, 4),
])
@pytest.mark.parametrize("lr_dtype", [jnp.float32, jnp.bfloat16])
def test_w4a4_kernel_matches_ref(rng, m, k, n, r, lr_dtype):
    xq, sx, wpacked, sw, xv, u = _make_w4a4_problem(rng, m, k, n, r, lr_dtype)
    got = w4a4_lowrank_matmul_kernel(
        xq, sx, wpacked, sw, xv, None if u is None else jnp.asarray(u, jnp.float32),
        bm=8, bn=16, bk=32, interpret=True,
    )
    want = ref.w4a4_lowrank_matmul_ref(xq, sx, wpacked, sw, xv,
                                       None if u is None else jnp.asarray(u, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("blocks", [(8, 16, 32), (16, 32, 64), (8, 8, 128)])
def test_w4a4_kernel_block_shape_invariance(rng, blocks):
    bm, bn, bk = blocks
    xq, sx, wpacked, sw, xv, u = _make_w4a4_problem(rng, 32, 128, 64, 8, jnp.float32)
    got = w4a4_lowrank_matmul_kernel(xq, sx, wpacked, sw, xv, u,
                                     bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.w4a4_lowrank_matmul_ref(xq, sx, wpacked, sw, xv, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_w4a4_end_to_end_matches_qlinear_int8(rng):
    """ops.w4a4_lowrank_matmul (pallas path) == QLinear int8 path."""
    import dataclasses
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, r = 128, 64, 8
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (d_out, 1)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d_out, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d_in, r)), jnp.float32)
    ql = make_qlinear(q, s, u, v, impl="int8", lr_dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((24, d_in)), jnp.float32)
    a = qlinear_apply(ql, x)
    b = qlinear_apply(dataclasses.replace(ql, impl="pallas"), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# act quant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k", [(16, 64), (128, 32), (256, 512)])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_actquant_kernel_matches_ref(rng, m, k, bits, dtype):
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    q, s = act_quant_kernel(x, bits=bits, clip_ratio=0.9, bm=min(16, m), interpret=True)
    qr, sr = ref.act_quant_ref(x, bits=bits, clip_ratio=0.9)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    dq = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    else:
        # bf16 inputs land exactly on .5 grid ties; a 1-ulp difference in the
        # scale flips the round — allow ±1 on a vanishing fraction
        assert dq.max() <= 1
        assert (dq > 0).mean() < 1e-3


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 8).map(lambda i: 8 * i),
    k=st.sampled_from([16, 64, 256]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_actquant_property_roundtrip_bound(m, k, bits, seed):
    """|x - q·s| ≤ s/2 elementwise (within the clip range) and q on-grid."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)) * rng.uniform(0.1, 10), jnp.float32)
    q, s = act_quant_kernel(x, bits=bits, clip_ratio=1.0, bm=8, interpret=True)
    q = np.asarray(q, np.int32)
    s = np.asarray(s)
    qmax = 2 ** (bits - 1) - 1
    assert q.max() <= qmax and q.min() >= -qmax - 1
    recon = q * s
    assert np.all(np.abs(np.asarray(x) - recon) <= s / 2 + 1e-6)


# ---------------------------------------------------------------------------
# hadamard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,d", [(8, 16), (32, 128), (16, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_kernel_matches_ref(rng, m, d, dtype):
    x = jnp.asarray(rng.standard_normal((m, d)), dtype)
    got = fwht_kernel(x, bm=8, interpret=True)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


@settings(max_examples=20, deadline=None)
@given(d_log=st.integers(2, 9), seed=st.integers(0, 2**31 - 1))
def test_fwht_property_orthogonal(d_log, seed):
    """WHT preserves norms and double application is the identity."""
    d = 2 ** d_log
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((8, d)), jnp.float32)
    y = fwht_kernel(x, bm=8, interpret=True)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-4,
    )
    z = fwht_kernel(y, bm=8, interpret=True)
    np.testing.assert_allclose(np.asarray(z), np.asarray(x), rtol=1e-4, atol=1e-4)


def test_ops_padding_path(rng):
    """Non-multiple M exercises the pad/slice wrapper."""
    x = jnp.asarray(rng.standard_normal((13, 64)), jnp.float32)
    q, s = ops.act_quant(x, QuantSpec(bits=4))
    assert q.shape == (13, 64) and s.shape == (13, 1)
    y = ops.fwht(jnp.asarray(rng.standard_normal((7, 32)), jnp.float32))
    assert y.shape == (7, 32)

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq,skv,bq,bkv", [(32, 32, 8, 8), (64, 64, 16, 32), (16, 128, 16, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(rng, sq, skv, bq, bkv, causal):
    from repro.kernels.flash_attn import flash_attention_kernel

    if causal and sq != skv:
        pytest.skip("causal tile math assumes aligned q/kv starts")
    bh, d = 3, 16
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    got = flash_attention_kernel(q, k, v, 0.25, causal=causal, bq=bq, bkv=bkv,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, 0.25, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_gqa_wrapper_matches_model_attention(rng):
    from repro.kernels.ops import flash_attention
    from repro.models.common import attention, causal_mask

    b, s, h, kh, d = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.float32)
    got = flash_attention(q, k, v, 0.25, causal=True, bq=8, bkv=8)
    want = attention(q, k, v, causal_mask(s, s, 0), 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_paged_flash_attention_matches_dense_gather(rng):
    """The in-kernel page gather == dense attention over the logically
    contiguous KV, for shuffled non-contiguous page placements and per-
    sequence lengths; unowned/null pages hold garbage that must not leak."""
    from repro.kernels.ops import paged_flash_attention

    b, h, kh, d, page, mpb, npages = 3, 4, 2, 16, 4, 6, 16
    g = h // kh
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    # pool full of garbage; only block-table-owned positions are real
    k_pages = jnp.asarray(rng.standard_normal((npages, page, kh, d)) * 50,
                          jnp.float32)
    v_pages = jnp.asarray(rng.standard_normal((npages, page, kh, d)) * 50,
                          jnp.float32)
    lengths = np.asarray([5, 24, 13], np.int32)
    ids = rng.permutation(np.arange(1, npages))  # non-contiguous, page 0 never owned
    bt = np.zeros((b, mpb), np.int32)
    taken = 0
    for i in range(b):
        n = -(-int(lengths[i]) // page)
        bt[i, :n] = ids[taken:taken + n]
        taken += n
    got = paged_flash_attention(q, k_pages, v_pages, bt, lengths, 0.25)

    # reference: gather each sequence's pages contiguously, truncate to its
    # length, plain softmax attention per query head
    for i in range(b):
        L = int(lengths[i])
        kk = k_pages[bt[i]].reshape(mpb * page, kh, d)[:L]
        vv = v_pages[bt[i]].reshape(mpb * page, kh, d)[:L]
        for hh in range(h):
            c = hh // g  # kv head of this query head's group
            s = (q[i, hh] * 0.25) @ kk[:, c].T
            want = jax.nn.softmax(s) @ vv[:, c]
            np.testing.assert_allclose(np.asarray(got[i, hh]),
                                       np.asarray(want),
                                       rtol=2e-4, atol=2e-4)
