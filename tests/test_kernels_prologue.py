"""Fused activation prologue (rotate → quantize → low-rank project) vs. the
three-pass reference chain, plus the end-to-end ``w4a4_lrc_forward`` path on
non-multiple-of-block shapes (all interpret mode)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import QuantSpec, pack_int4
from repro.kernels import ops, ref
from repro.kernels.prologue import fused_prologue_kernel


def _assert_prologue_matches(x, v, rotate, bm):
    got_q, got_s, got_xv = fused_prologue_kernel(
        x, v, bits=4, clip_ratio=0.9, rotate=rotate, bm=bm, interpret=True
    )
    want_q, want_s, want_xv = ref.fused_prologue_ref(
        x, v, bits=4, clip_ratio=0.9, rotate=rotate
    )
    # acceptance: xq bitwise, sx/xv within 1e-5
    np.testing.assert_array_equal(np.asarray(got_q), np.asarray(want_q))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-5, atol=1e-5)
    if v is None:
        assert got_xv is None and want_xv is None
    else:
        np.testing.assert_allclose(np.asarray(got_xv), np.asarray(want_xv),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel vs. three-pass reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,r", [
    (16, 64, 0),     # rank-0: quantize only
    (16, 64, 8),
    (32, 128, 16),
    (8, 256, 4),
])
@pytest.mark.parametrize("rotate", [False, True])
def test_prologue_matches_three_pass_ref(rng, m, k, r, rotate):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((k, r)), jnp.float32) if r else None
    _assert_prologue_matches(x, v, rotate, bm=8)


def test_prologue_block_shape_invariance(rng):
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    for bm in (8, 16, 32):
        _assert_prologue_matches(x, v, rotate=True, bm=bm)


def test_prologue_bf16_inputs_close(rng):
    """bf16 activations: scales/projection track the reference within bf16
    noise (xq bitwise equality is only guaranteed for f32 inputs)."""
    x = jnp.asarray(rng.standard_normal((16, 128)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    got_q, got_s, got_xv = fused_prologue_kernel(
        x, v, bits=4, clip_ratio=0.9, rotate=False, bm=8, interpret=True
    )
    want_q, want_s, want_xv = ref.fused_prologue_ref(
        x, v, bits=4, clip_ratio=0.9, rotate=False
    )
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_xv), np.asarray(want_xv),
                               rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(got_q, np.int32) - np.asarray(want_q, np.int32)).max() <= 1


def test_ops_fused_prologue_nonmultiple_m(rng):
    """Wrapper pads/slices M that is not a block multiple."""
    x = jnp.asarray(rng.standard_normal((13, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((64, 5)), jnp.float32)
    q, s, xv = ops.fused_prologue(x, v, QuantSpec(bits=4, clip_ratio=0.9),
                                  rotate=True, bm=8)
    assert q.shape == (13, 64) and s.shape == (13, 1) and xv.shape == (13, 5)
    wq, ws, wxv = ref.fused_prologue_ref(x, v, bits=4, clip_ratio=0.9,
                                         rotate=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(wq))
    np.testing.assert_allclose(np.asarray(xv), np.asarray(wxv),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end forward (prologue + GEMM/epilogue) with zero-padding
# ---------------------------------------------------------------------------


def _forward_ref(x, q_out_in, scales, u, v, spec, rotate=False):
    xq, sx, xv = ref.fused_prologue_ref(x, v, bits=spec.bits,
                                        clip_ratio=spec.clip_ratio,
                                        rotate=rotate)
    wpacked = pack_int4(q_out_in).T
    sw = scales.reshape(1, -1)
    return ref.w4a4_lowrank_matmul_ref(xq, sx, wpacked, sw, xv, u)


@pytest.mark.parametrize("m,k,n,r", [
    (16, 64, 32, 0),      # decode-regime, block-aligned, rank-0
    (13, 96, 80, 5),      # nothing is a multiple of any block size
    (24, 128, 100, 8),    # odd N only (odd-MLP-width case)
    (7, 64, 64, 3),       # tiny M
])
def test_w4a4_lrc_forward_matches_ref(rng, m, k, n, r):
    spec = QuantSpec(bits=4, clip_ratio=0.9)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    q = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.2, (n,)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((n, r)), jnp.float32) if r else None
    v = jnp.asarray(rng.standard_normal((k, r)), jnp.float32) if r else None
    got = ops.w4a4_lrc_forward(x, pack_int4(q).T, s, u, v, spec)
    want = _forward_ref(x, q, s, u, v, spec)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_w4a4_lrc_forward_rotated(rng):
    """Online rotation inside the prologue (pow2 K) end to end."""
    m, k, n, r = 12, 128, 48, 6
    spec = QuantSpec(bits=4, clip_ratio=0.9)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    q = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.2, (n,)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((k, r)), jnp.float32)
    got = ops.w4a4_lrc_forward(x, pack_int4(q).T, s, u, v, spec, rotate=True)
    want = _forward_ref(x, q, s, u, v, spec, rotate=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_w4a4_lrc_forward_explicit_blocks(rng):
    """Caller-pinned blocks (the autotune-table override) stay exact."""
    m, k, n, r = 32, 128, 64, 8
    spec = QuantSpec(bits=4, clip_ratio=0.9)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    q = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.2, (n,)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((k, r)), jnp.float32)
    want = _forward_ref(x, q, s, u, v, spec)
    for blocks in [(8, 16, 32), (16, 64, 64), (32, 32, 128)]:
        got = ops.w4a4_lrc_forward(x, pack_int4(q).T, s, u, v, spec,
                                   blocks=blocks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_select_blocks_regimes():
    """The autotune table keys on the serving regime and clamps to dims.
    select_blocks returns the full Plan NamedTuple (read .bm/.bn/.bk/.br)."""
    p = ops.select_blocks(16, 4096, 11008, 128)   # decode
    assert p.bm <= 16 and p.bn >= 128 and p.br <= 128
    assert ops.select_blocks(256, 4096, 11008, 128).bm == 128   # mixed
    assert ops.select_blocks(2048, 4096, 11008, 128).bm == 256  # prefill
    # tiny problems clamp every block below the table entry
    p4 = ops.select_blocks(8, 64, 32, 0)
    assert p4.bm <= 8 and p4.bn <= 32 and p4.bk <= 64 and p4.br <= 8


def test_qlinear_pallas_impl_matches_int8_odd_shapes(rng):
    """QLinear(impl=pallas) now survives non-multiple d_in/d_out widths."""
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, r = 96, 80, 8
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (d_out, 1)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d_out, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d_in, r)), jnp.float32)
    ql = make_qlinear(q, s, u, v, impl="int8", lr_dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((13, d_in)), jnp.float32)
    a = qlinear_apply(ql, x)
    b = qlinear_apply(dataclasses.replace(ql, impl="pallas"), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_qlinear_pallas_groupwise_runs_kernels(rng):
    """Group-wise-calibrated layers (paper Table 2) now run the kernel
    paths: impl='pallas' serves them with the (M, K/g) scale plane (the
    engine's auto-retag hits every leaf) and matches the grouped int8 GEMM
    reference semantics."""
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, g = 128, 64, 32
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (d_out, 1)), jnp.float32)
    ql = make_qlinear(q, s, act_group=g, impl="int8")
    x = jnp.asarray(rng.standard_normal((8, d_in)), jnp.float32)
    a = qlinear_apply(ql, x)
    b = qlinear_apply(dataclasses.replace(ql, impl="pallas"), x)
    # rank-0 integer math is exact on both paths
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_retag_qlinear_impl(rng):
    from repro.quant.qlinear import make_qlinear, retag_qlinear_impl

    q = jnp.asarray(rng.integers(-8, 8, (16, 32)), jnp.int8)
    s = jnp.ones((16, 1), jnp.float32)
    tree = {"a": make_qlinear(q, s, impl="sim"),
            "b": {"w": jnp.ones((4, 4)), "q": make_qlinear(q, s, impl="int8")}}
    out = retag_qlinear_impl(tree, "pallas")
    assert out["a"].impl == "pallas" and out["b"]["q"].impl == "pallas"
    np.testing.assert_array_equal(np.asarray(out["b"]["w"]), np.ones((4, 4)))


def test_w4a4_lrc_forward_large_r_fallback(rng):
    """When nothing fits the VMEM budgets (forced via an explicit context)
    the wrapper silently takes the unfused three-pass chain — results must
    be identical."""
    m, k, n, r = 16, 64, 32, 8
    spec = QuantSpec(bits=4, clip_ratio=0.9)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    q = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.2, (n,)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((k, r)), jnp.float32)
    want = ops.w4a4_lrc_forward(x, pack_int4(q).T, s, u, v, spec, rotate=True)
    tiny = ops.KernelContext().with_vmem_budgets(fused=0, prologue=1)
    assert tiny.resolve_plan(m, k, n, r, rotate=True).path == "unfused"
    got = ops.w4a4_lrc_forward(x, pack_int4(q).T, s, u, v, spec, rotate=True,
                               ctx=tiny)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_prologue_byte_model_decode_win():
    """The roofline byte model records the fusion ladder at decode shapes:
    chained (PR 1 prologue) well below unfused, and the single-kernel fused
    path strictly below chained by exactly the eliminated xq/sx/xv
    round-trip (acceptance criterion).  The legacy boolean spelling keeps
    mapping onto unfused/chained."""
    from repro.launch.roofline import prologue_activation_bytes

    for k, n in [(4096, 11008), (5120, 13824), (8192, 28672)]:
        for r in (128, 256, 512, 1024):
            unfused = prologue_activation_bytes(16, k, r, rotate=True,
                                                path="unfused")
            chained = prologue_activation_bytes(16, k, r, rotate=True,
                                                path="chained")
            fused = prologue_activation_bytes(16, k, r, rotate=True,
                                              path="fused")
            assert unfused / chained >= 1.5, (k, r, unfused / chained)
            assert chained / fused >= 2.0, (k, r, chained / fused)
            # chained − fused = the M×K xq write+read (+ sx/xv round-trip)
            assert chained - fused == 2 * (16 * k + 4 * 16 + 4 * 16 * r)
            # legacy boolean spelling
            assert prologue_activation_bytes(16, k, r, rotate=True,
                                             fused=True) == chained
            assert prologue_activation_bytes(16, k, r, rotate=True,
                                             fused=False) == unfused
